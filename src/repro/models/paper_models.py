"""The paper's own architectures (Appendix A):

* MLP    — 4 fully-connected layers (512, 256, 128 hidden; 10 out), ReLU.
* CNN    — 3 conv layers (32/64/64 ch, 3×3, pad 1) + FC 128, 64, out.
* VGG16  — Simonyan & Zisserman cfg-D, with a width multiplier for
           CPU-tractable validation runs (full width exercised via shapes).

All weights go through the gain-corrected He initialiser — these are the
models Figures 1–4, 6, 7 are made with.
"""
from __future__ import annotations

from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.core.initialisation import InitConfig, scaled_init
from .common import KeyGen

PyTree = Any

__all__ = ["init_mlp", "mlp_forward", "init_cnn", "cnn_forward", "init_vgg16", "vgg16_forward", "classifier_loss", "accuracy"]


# ----------------------------------------------------------------- MLP
def init_mlp(
    init_cfg: InitConfig,
    key: jax.Array,
    in_dim: int = 784,
    hidden: Sequence[int] = (512, 256, 128),
    n_classes: int = 10,
) -> PyTree:
    kg = KeyGen(key)
    dims = [in_dim, *hidden, n_classes]
    return {
        f"fc{i}": {
            "w": scaled_init(init_cfg, kg(), (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
        for i in range(len(dims) - 1)
    }


def mlp_forward(params: PyTree, x: jax.Array) -> jax.Array:
    """x (..., H, W, C) or (..., D) → logits (..., n_classes)."""
    n_layers = len(params)
    d_in = params["fc0"]["w"].shape[0]
    # merge however many trailing axes multiply to d_in (image → flat vector)
    if x.shape[-1] != d_in:
        k, prod = x.ndim, 1
        while prod < d_in and k > 0:
            k -= 1
            prod *= x.shape[k]
        if prod != d_in:
            raise ValueError(f"cannot flatten {x.shape} to feature dim {d_in}")
        x = x.reshape(x.shape[:k] + (d_in,))
    for i in range(n_layers):
        p = params[f"fc{i}"]
        x = x @ p["w"] + p["b"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


# ----------------------------------------------------------------- CNN
def _conv_init(init_cfg: InitConfig, key: jax.Array, kh: int, kw: int, cin: int, cout: int) -> PyTree:
    return {
        "w": scaled_init(init_cfg, key, (kh, kw, cin, cout), jnp.float32),
        "b": jnp.zeros((cout,), jnp.float32),
    }


def _conv(p: PyTree, x: jax.Array) -> jax.Array:
    """NHWC 3×3 same conv."""
    y = jax.lax.conv_general_dilated(
        x, p["w"], window_strides=(1, 1), padding="SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
    )
    return y + p["b"]


def _maxpool2(x: jax.Array) -> jax.Array:
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def init_cnn(
    init_cfg: InitConfig,
    key: jax.Array,
    image_shape: tuple[int, int, int] = (32, 32, 10),
    channels: Sequence[int] = (32, 64, 64),
    fc_hidden: Sequence[int] = (128, 64),
    n_classes: int = 17,
) -> PyTree:
    kg = KeyGen(key)
    h, w, cin = image_shape
    params: PyTree = {}
    c_prev = cin
    for i, c in enumerate(channels):
        params[f"conv{i}"] = _conv_init(init_cfg, kg(), 3, 3, c_prev, c)
        c_prev = c
        h, w = h // 2, w // 2  # one maxpool per conv
    dims = [h * w * c_prev, *fc_hidden, n_classes]
    for i in range(len(dims) - 1):
        params[f"fc{i}"] = {
            "w": scaled_init(init_cfg, kg(), (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
    return params


def cnn_forward(params: PyTree, x: jax.Array) -> jax.Array:
    """x (B, H, W, C) → logits."""
    i = 0
    while f"conv{i}" in params:
        x = jax.nn.relu(_conv(params[f"conv{i}"], x))
        x = _maxpool2(x)
        i += 1
    x = x.reshape(x.shape[0], -1)
    i = 0
    while f"fc{i}" in params:
        p = params[f"fc{i}"]
        x = x @ p["w"] + p["b"]
        if f"fc{i+1}" in params:
            x = jax.nn.relu(x)
        i += 1
    return x


# ----------------------------------------------------------------- VGG16
_VGG_D = (64, 64, "M", 128, 128, "M", 256, 256, 256, "M", 512, 512, 512, "M", 512, 512, 512, "M")


def init_vgg16(
    init_cfg: InitConfig,
    key: jax.Array,
    image_shape: tuple[int, int, int] = (32, 32, 3),
    n_classes: int = 10,
    width_mult: float = 1.0,
    fc_dim: int = 4096,
) -> PyTree:
    kg = KeyGen(key)
    h, w, cin = image_shape
    params: PyTree = {}
    c_prev = cin
    conv_i = 0
    for entry in _VGG_D:
        if entry == "M":
            h, w = h // 2, w // 2
            continue
        c = max(8, int(entry * width_mult))
        params[f"conv{conv_i}"] = _conv_init(init_cfg, kg(), 3, 3, c_prev, c)
        c_prev = c
        conv_i += 1
    fdim = max(16, int(fc_dim * width_mult))
    dims = [h * w * c_prev, fdim, fdim, n_classes]
    for i in range(3):
        params[f"fc{i}"] = {
            "w": scaled_init(init_cfg, kg(), (dims[i], dims[i + 1]), jnp.float32),
            "b": jnp.zeros((dims[i + 1],), jnp.float32),
        }
    return params


def vgg16_forward(params: PyTree, x: jax.Array) -> jax.Array:
    conv_i = 0
    for entry in _VGG_D:
        if entry == "M":
            x = _maxpool2(x)
            continue
        x = jax.nn.relu(_conv(params[f"conv{conv_i}"], x))
        conv_i += 1
    x = x.reshape(x.shape[0], -1)
    for i in range(3):
        p = params[f"fc{i}"]
        x = x @ p["w"] + p["b"]
        if i < 2:
            x = jax.nn.relu(x)
    return x


# ----------------------------------------------------------------- losses
def classifier_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean softmax cross-entropy (the paper's test metric is exactly this)."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    picked = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -picked.mean()


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return (logits.argmax(-1) == labels).mean()

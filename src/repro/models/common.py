"""Shared building blocks for the model zoo.

Everything is functional: ``init_*`` returns a params (nested-dict) pytree,
``apply``-style functions are pure.  All random weight draws go through
``repro.core.initialisation.scaled_init`` so the paper's ‖v_steady‖⁻¹ gain
correction reaches every architecture uniformly (DESIGN.md §4).  Structured
parameters (norm scales, biases, decay spectra) bypass the gain.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.core.initialisation import InitConfig, scaled_init

PyTree = Any

__all__ = [
    "KeyGen",
    "dense_init",
    "norm_init",
    "norm_apply",
    "rope_freqs",
    "apply_rope",
    "ACTIVATIONS",
]


class KeyGen:
    """Sequential PRNG splitter so init code reads linearly."""

    def __init__(self, key: jax.Array):
        self._key = key

    def __call__(self) -> jax.Array:
        self._key, sub = jax.random.split(self._key)
        return sub


def dense_init(
    init_cfg: InitConfig,
    key: jax.Array,
    shape: tuple[int, ...],
    dtype=jnp.bfloat16,
    bias: bool = False,
) -> PyTree:
    """A (gain-corrected) dense weight, optionally with a zero bias."""
    p = {"w": scaled_init(init_cfg, key, shape, jnp.float32).astype(dtype)}
    if bias:
        p["b"] = jnp.zeros((shape[-1],), dtype)
    return p


def norm_init(d: int, kind: str, dtype=jnp.bfloat16) -> PyTree:
    """RMSNorm (scale only) or LayerNorm (scale + bias); init is structured
    (ones/zeros) and therefore *not* gain-corrected."""
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def norm_apply(p: PyTree, x: jax.Array, kind: str, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        y = xf * jax.lax.rsqrt(jnp.mean(jnp.square(xf), axis=-1, keepdims=True) + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    if kind == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)
    raise ValueError(f"unknown norm kind {kind}")


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings, (head_dim // 2,)."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding.

    x: (..., S, H, hd); positions: broadcastable to (..., S) absolute indices.
    fp32 trig, cast back to x.dtype.
    """
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv[None, :]  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]  # (..., S, 1, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


ACTIVATIONS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
    "tanh": jnp.tanh,
}

"""Partitioning the global dataset across FL nodes (paper §3, Appendix A).

The paper uses iid and non-iid (Zipf, α=1.8) label distributions, with
disjoint local datasets D_i, D_i ∩ D_j = ∅, and (on expectation) equal items
per node — which is what justifies β_i ≈ 1/(k_i+1) in Eq. 2.
"""
from __future__ import annotations

import numpy as np

from .synthetic import ImageDataset

__all__ = ["partition_iid", "partition_zipf", "node_datasets"]


def partition_iid(n_samples: int, n_nodes: int, seed: int = 0) -> list[np.ndarray]:
    """Disjoint uniform split: every node gets n_samples // n_nodes indices."""
    rng = np.random.default_rng(seed)
    per = n_samples // n_nodes
    perm = rng.permutation(n_samples)[: per * n_nodes]
    return [perm[i * per : (i + 1) * per].astype(np.int64) for i in range(n_nodes)]


def partition_zipf(
    labels: np.ndarray, n_nodes: int, alpha: float = 1.8, items_per_node: int | None = None, seed: int = 0
) -> list[np.ndarray]:
    """Non-iid split: node i draws labels with a Zipf(α) preference over a
    node-specific class ranking (paper cfg. B: Zipf α=1.8).

    Every node ends up with the same number of items (equal |D_i|, as §3
    assumes), but with skewed class proportions: each node's most-preferred
    class dominates with weight ∝ rank^-α.
    """
    rng = np.random.default_rng(seed)
    labels = np.asarray(labels)
    n_classes = int(labels.max()) + 1
    n_samples = len(labels)
    per = items_per_node if items_per_node is not None else n_samples // n_nodes

    by_class = [list(rng.permutation(np.nonzero(labels == c)[0])) for c in range(n_classes)]
    ranks = np.arange(1, n_classes + 1, dtype=np.float64)
    zipf_w = ranks ** (-alpha)
    zipf_w /= zipf_w.sum()

    out: list[np.ndarray] = []
    for i in range(n_nodes):
        pref = rng.permutation(n_classes)  # node-specific class ranking
        w = np.empty(n_classes)
        w[pref] = zipf_w
        chosen: list[int] = []
        # draw class for each item; fall back to the least-depleted class
        cls_draws = rng.choice(n_classes, size=per, p=w)
        for c in cls_draws:
            if not by_class[c]:
                avail = [k for k in range(n_classes) if by_class[k]]
                if not avail:
                    break
                c = max(avail, key=lambda k: len(by_class[k]))
            chosen.append(by_class[c].pop())
        out.append(np.asarray(chosen, dtype=np.int64))
    return out


def node_datasets(ds: ImageDataset, parts: list[np.ndarray]) -> tuple[np.ndarray, np.ndarray]:
    """Stack per-node partitions into (n_nodes, per_node, ...) arrays.

    Truncates to the minimum partition size so the stack is rectangular —
    the vectorised-ensemble trainer wants node-major dense arrays.
    """
    per = min(len(p) for p in parts)
    xs = np.stack([ds.x[p[:per]] for p in parts])
    ys = np.stack([ds.y[p[:per]] for p in parts])
    return xs, ys

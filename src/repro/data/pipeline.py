"""Batching pipeline for the vectorised node ensemble.

All nodes step in lock-step (one communication round = ``b`` local
minibatches, Appendix A: minibatch 16, b = 8), so the natural batch layout is
node-major: ``(n_nodes, batch, ...)``.

Two renderings of the SAME deterministic sample order (DESIGN.md §11):

* ``batch_index_schedule`` — the whole gather schedule as one int32 array,
  precomputed on host and shipped to the device once; the fused round
  executor (``repro.fed.executor``) takes each round's minibatches by
  on-device gather from it.
* ``node_batch_iterator`` — the host fallback: an infinite iterator that
  draws the identical per-epoch permutations and yields batches via a single
  batched gather (no per-node Python loop).  For a given seed the iterator's
  k-th batch selects exactly ``batch_index_schedule(...)[k]``.

Epoch semantics (shared): every epoch draws one fresh permutation per node;
the ``per_node mod batch_size`` remainder is dropped; all nodes cross epoch
boundaries together (cursors advance in lock-step).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = [
    "NodeBatches",
    "batch_index_schedule",
    "node_batch_iterator",
    "token_batch_iterator",
]


@dataclasses.dataclass(frozen=True)
class NodeBatches:
    x: np.ndarray  # (n_nodes, batch, ...)
    y: np.ndarray  # (n_nodes, batch)


def _epoch_orders(rng: np.random.Generator, n_nodes: int, per_node: int) -> np.ndarray:
    """One epoch's per-node permutations, drawn in a single vectorised call.

    Both the schedule and the iterator consume the generator through this
    helper, which is what keeps their sample orders identical.
    """
    base = np.tile(np.arange(per_node, dtype=np.int64), (n_nodes, 1))
    return rng.permuted(base, axis=1)


def batch_index_schedule(
    per_node: int, n_nodes: int, batch_size: int, n_batches: int, seed: int = 0
) -> np.ndarray:
    """Precompute the full gather schedule: (n_batches, n_nodes, batch_size).

    ``schedule[k, i]`` are the sample indices node i trains on in its k-th
    minibatch.  Deterministic in ``seed`` and bit-identical to the order
    ``node_batch_iterator`` yields.
    """
    if batch_size > per_node:
        raise ValueError(f"batch_size {batch_size} > per_node {per_node}")
    rng = np.random.default_rng(seed)
    bpe = per_node // batch_size  # batches per epoch (remainder dropped)
    n_epochs = -(-n_batches // bpe)
    chunks = []
    for _ in range(n_epochs):
        orders = _epoch_orders(rng, n_nodes, per_node)
        ep = orders[:, : bpe * batch_size].reshape(n_nodes, bpe, batch_size)
        chunks.append(ep.transpose(1, 0, 2))  # (bpe, n_nodes, batch)
    return np.concatenate(chunks)[:n_batches].astype(np.int32)


def node_batch_iterator(
    xs: np.ndarray, ys: np.ndarray, batch_size: int, seed: int = 0
) -> Iterator[NodeBatches]:
    """Infinite iterator of per-node minibatches with per-node shuffling.

    Host fallback of ``batch_index_schedule``: same seed ⇒ same batches, in
    the same order.  Each yield is one batched gather over the node axis.
    """
    n_nodes, per_node = ys.shape[:2]
    if batch_size > per_node:
        raise ValueError(f"batch_size {batch_size} > per_node {per_node}")
    rng = np.random.default_rng(seed)
    bpe = per_node // batch_size
    node_idx = np.arange(n_nodes)[:, None]
    while True:
        orders = _epoch_orders(rng, n_nodes, per_node)
        for b in range(bpe):
            take = orders[:, b * batch_size : (b + 1) * batch_size]
            yield NodeBatches(x=xs[node_idx, take], y=ys[node_idx, take])


def token_batch_iterator(
    tokens_per_node: np.ndarray, batch_size: int, seq_len: int, seed: int = 0
) -> Iterator[NodeBatches]:
    """LM batches: x = tokens[t:t+L], y = tokens[t+1:t+L+1], per node.

    The window gather is fully vectorised: start offsets broadcast against
    ``arange(seq_len)`` and one fancy-index pulls every (node, batch) window.
    """
    n_nodes, stream_len = tokens_per_node.shape
    rng = np.random.default_rng(seed)
    max_start = stream_len - seq_len - 1
    node_idx = np.arange(n_nodes)[:, None, None]
    offsets = np.arange(seq_len)
    while True:
        starts = rng.integers(0, max_start, size=(n_nodes, batch_size))
        win = starts[:, :, None] + offsets  # (n_nodes, batch, seq_len)
        x = tokens_per_node[node_idx, win].astype(np.int32)
        y = tokens_per_node[node_idx, win + 1].astype(np.int32)
        yield NodeBatches(x=x, y=y)

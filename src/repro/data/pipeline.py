"""Batching pipeline for the vectorised node ensemble.

All nodes step in lock-step (one communication round = ``b`` local
minibatches, Appendix A: minibatch 16, b = 8), so the natural batch layout is
node-major: ``(n_nodes, batch, ...)``.  The iterator is a deterministic,
seeded, infinitely-repeating shuffle per node — a faithful stand-in for each
device's local data loader.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

__all__ = ["NodeBatches", "node_batch_iterator", "token_batch_iterator"]


@dataclasses.dataclass(frozen=True)
class NodeBatches:
    x: np.ndarray  # (n_nodes, batch, ...)
    y: np.ndarray  # (n_nodes, batch)


def node_batch_iterator(
    xs: np.ndarray, ys: np.ndarray, batch_size: int, seed: int = 0
) -> Iterator[NodeBatches]:
    """Infinite iterator of per-node minibatches with per-node shuffling."""
    n_nodes, per_node = ys.shape[:2]
    rng = np.random.default_rng(seed)
    orders = np.stack([rng.permutation(per_node) for _ in range(n_nodes)])
    cursors = np.zeros(n_nodes, dtype=np.int64)
    while True:
        bx = np.empty((n_nodes, batch_size) + xs.shape[2:], dtype=xs.dtype)
        by = np.empty((n_nodes, batch_size), dtype=ys.dtype)
        for i in range(n_nodes):
            take = orders[i][cursors[i] : cursors[i] + batch_size]
            if len(take) < batch_size:  # epoch boundary: reshuffle
                orders[i] = rng.permutation(per_node)
                cursors[i] = 0
                take = orders[i][:batch_size]
            bx[i] = xs[i, take]
            by[i] = ys[i, take]
            cursors[i] += batch_size
        yield NodeBatches(x=bx, y=by)


def token_batch_iterator(
    tokens_per_node: np.ndarray, batch_size: int, seq_len: int, seed: int = 0
) -> Iterator[NodeBatches]:
    """LM batches: x = tokens[t:t+L], y = tokens[t+1:t+L+1], per node."""
    n_nodes, stream_len = tokens_per_node.shape
    rng = np.random.default_rng(seed)
    max_start = stream_len - seq_len - 1
    while True:
        starts = rng.integers(0, max_start, size=(n_nodes, batch_size))
        x = np.empty((n_nodes, batch_size, seq_len), dtype=np.int32)
        y = np.empty((n_nodes, batch_size, seq_len), dtype=np.int32)
        for i in range(n_nodes):
            for b in range(batch_size):
                s = starts[i, b]
                x[i, b] = tokens_per_node[i, s : s + seq_len]
                y[i, b] = tokens_per_node[i, s + 1 : s + seq_len + 1]
        yield NodeBatches(x=x, y=y)

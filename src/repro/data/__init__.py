"""Synthetic datasets, FL partitioning and batching."""
from .partition import node_datasets, partition_iid, partition_zipf
from .pipeline import NodeBatches, batch_index_schedule, node_batch_iterator, token_batch_iterator
from .synthetic import (
    ImageDataset,
    cifar10_like,
    make_image_classification,
    make_token_stream,
    mnist_like,
    so2sat_like,
)

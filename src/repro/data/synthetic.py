"""Deterministic synthetic datasets matching the paper's workloads.

MNIST / So2Sat LCZ42 / CIFAR-10 are not available offline, so we generate
seeded class-conditional Gaussian-mixture image datasets with matched shapes
and class counts (DESIGN.md §6.1).  The mixture is constructed so that the
Bayes-optimal classifier is non-trivial (classes overlap) and learnable by
the paper's MLP/CNN in a few hundred steps — the dynamics the paper studies
(plateau scaling, σ trajectories, failure robustness) are init/aggregation
phenomena, not dataset-specific.

Token-LM streams back the transformer-zoo smoke tests and examples: a seeded
order-2 Markov chain over the vocabulary so that next-token prediction has
learnable structure.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["ImageDataset", "make_image_classification", "mnist_like", "so2sat_like", "cifar10_like", "make_token_stream"]


@dataclasses.dataclass(frozen=True)
class ImageDataset:
    x: np.ndarray  # (N, H, W, C) float32
    y: np.ndarray  # (N,) int32
    n_classes: int
    name: str

    def __len__(self) -> int:
        return self.x.shape[0]


def make_image_classification(
    n_samples: int,
    image_shape: tuple[int, int, int],
    n_classes: int,
    seed: int = 0,
    class_sep: float = 2.0,
    n_prototypes: int = 4,
    name: str = "synthetic",
) -> ImageDataset:
    """Class-conditional Gaussian mixture in image space.

    Each class has ``n_prototypes`` smooth prototype images (low-frequency
    random fields); a sample is a random prototype of its class plus white
    noise.  ``class_sep`` scales prototype separation vs. noise.
    """
    rng = np.random.default_rng(seed)
    h, w, c = image_shape
    d = h * w * c
    # low-frequency prototypes: random coefficients on coarse 2D cosine basis
    n_basis = 8
    fy = np.cos(np.pi * np.arange(h)[:, None] * np.arange(n_basis)[None, :] / h)  # (h, B)
    fx = np.cos(np.pi * np.arange(w)[:, None] * np.arange(n_basis)[None, :] / w)  # (w, B)
    protos = np.empty((n_classes, n_prototypes, h, w, c), dtype=np.float32)
    for k in range(n_classes):
        for p in range(n_prototypes):
            coef = rng.standard_normal((n_basis, n_basis, c)).astype(np.float32)
            img = np.einsum("hb,wB,bBc->hwc", fy, fx, coef) / n_basis
            protos[k, p] = img * class_sep
    labels = rng.integers(0, n_classes, size=n_samples).astype(np.int32)
    proto_pick = rng.integers(0, n_prototypes, size=n_samples)
    x = protos[labels, proto_pick] + rng.standard_normal((n_samples, h, w, c)).astype(np.float32)
    # standardise like a real pipeline would
    x = (x - x.mean()) / (x.std() + 1e-8)
    return ImageDataset(x=x.astype(np.float32), y=labels, n_classes=n_classes, name=name)


def mnist_like(n_samples: int, seed: int = 0) -> ImageDataset:
    """28×28×1, 10 classes — stands in for MNIST (paper cfg. A/D)."""
    return make_image_classification(n_samples, (28, 28, 1), 10, seed=seed, name="mnist-like")


def so2sat_like(n_samples: int, seed: int = 0) -> ImageDataset:
    """32×32×10 (Sentinel-2 bands), 17 LCZ classes — stands in for So2Sat (cfg. B)."""
    return make_image_classification(n_samples, (32, 32, 10), 17, seed=seed, name="so2sat-like")


def cifar10_like(n_samples: int, seed: int = 0) -> ImageDataset:
    """32×32×3, 10 classes — stands in for CIFAR-10 (cfg. C)."""
    return make_image_classification(n_samples, (32, 32, 3), 10, seed=seed, name="cifar10-like")


def make_token_stream(n_tokens: int, vocab_size: int, seed: int = 0, order_bias: float = 8.0) -> np.ndarray:
    """Seeded token stream with learnable bigram structure.

    Transition logits are sparse-ish random; ``order_bias`` sharpens them so a
    small LM can reduce loss well below log(vocab).  Vocabulary is bucketed to
    keep the transition table small for huge vocabs.
    """
    rng = np.random.default_rng(seed)
    n_states = min(vocab_size, 1024)
    logits = rng.standard_normal((n_states, n_states)) * order_bias / np.sqrt(n_states)
    # top-32 sparsification per row keeps sampling cheap and structure strong
    top = 32
    part = np.argpartition(logits, -top, axis=1)[:, :-top]
    np.put_along_axis(logits, part, -np.inf, axis=1)
    p = np.exp(logits - logits.max(axis=1, keepdims=True))
    p /= p.sum(axis=1, keepdims=True)
    cdf = np.cumsum(p, axis=1)
    toks = np.empty(n_tokens, dtype=np.int64)
    s = int(rng.integers(n_states))
    u = rng.random(n_tokens)
    for t in range(n_tokens):
        s = int(np.searchsorted(cdf[s], u[t]))
        s = min(s, n_states - 1)
        toks[t] = s
    if vocab_size > n_states:
        # scatter bucket ids into the full vocab deterministically
        scatter = rng.permutation(vocab_size)[:n_states]
        toks = scatter[toks]
    return toks.astype(np.int32)
